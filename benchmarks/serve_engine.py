"""Continuous-batching engine: paged KV + chunked prefill vs fixed rows,
prefix sharing on vs off, and immune admission vs FIFO.

**Layout comparison** — two engine layouts run the same bursty heterogeneous
open-loop trace at **equal usable KV memory** (``budget_slots * max_cache``
cache tokens):

  * ``fixed`` — the PR 2 engine expressed as the degenerate paged config
    (``page_size == max_cache``, one page per slot, reserved whole at
    admission): ``budget_slots`` slots, worst-case row reservation.
  * ``paged`` — fine pages + chunked prefill over the same token budget, with
    ``2x`` the slots: admission reserves each request's *actual* worst case
    (``ceil(need/page)``), so mixed-length requests pack more concurrency into
    the same memory, and long prompts land chunk-by-chunk without stalling
    running decodes.

The budget is set so the immune gate *orders* rather than sheds here: when one
layout sheds a heavy the other serves, the served heavy lands in the tail and
p99-over-completions stops comparing like with like (the shed-vs-serve dynamic
itself is pinned by ``tests/test_serve_engine.py::TestImmuneVsFifo``).

**Prefix-sharing comparison** — the same engine twice, sharing on vs off, on
*system-prompt* traffic (a few fixed prefixes × many random suffixes) at an
identical tight page budget: share-off worst-cases every prompt from the free
list, share-on adopts the resident prefix pages with refcount++ and charges
only the unshared tail, so it packs more concurrent requests into the same
pages (or the same concurrency into fewer). Every share-on completion is also
replayed through one-shot ``decode.generate`` — the tokens must be bitwise
identical, and the JSON records that bit.

**Sampling comparison** — the same bursty trace served all-greedy vs
all-seeded-sampled (temperature 0.8 / top-p 0.9) at identical occupancy:
identical scheduling by construction, so the delta is the sampling lane in
the compiled decode step. Sampled completions are replayed through the
one-shot ``serve.api.generate`` facade and must be token-identical
(``sampling_parity_exact`` in the JSON) — same seed, same stream, either
backend.

**Memory-hierarchy comparisons** — two A/Bs for the persistent KV hierarchy:
``run_pinning`` serves returning-tenant bursts (separated by full drains) with
the pinned prefix cache on vs off at an equal page budget — later bursts must
cost at most 0.3x the cold engine's prefill tokens, with bitwise parity on
pinned-adopt completions; ``run_preemption`` serves one contention trace under
worst-case reservation vs immune-priority preemption at the same undersized
page budget — preemption must admit strictly deeper with a no-worse p99, with
bitwise parity on preempted-then-resumed completions. ``benchmarks/
regression_gate.py`` diffs these sections against a committed baseline in CI.

**Speculative-decoding comparison** — ``run_spec_decode`` serves the agentic
multi-turn trace (grown prompt prefixes, long decodes) with self-speculative
decoding on vs off at an equal page budget: the spec engine drafts ``k``
tokens per tick through a truncated-depth pass of the same weights and scores
all of them in one batched paged verify step. Accept rate, tick and warm
wall-clock speedup go to the JSON; ``spec_parity_exact`` pins the bitwise
accept oracle — the spec run's streams must equal the plain run's token for
token (see benchmarks/README.md for the cost model).

**Routing comparison** — ``run_routing`` drives the multi-replica placement
router (``serve/router.py``) over the multi-tenant fleet trace: immune
placement (prefix affinity -> anergy draining -> least remembered cost) vs
round-robin and join-shortest-queue at the same replica count and per-replica
page/pin budget. Immune p99 must be at most the best baseline's, affinity
hits positive, and per-request tokens bitwise identical across every policy
and replica count (``routing_parity_exact``).

**Failover comparison** — ``run_failover`` replays the fleet trace under a
seeded crash-of-1-of-``replicas`` fault plan (``serve/faults.py``; the
crashed replica rejoins cold later): the router's missed-deadline health
machine detects the death, evacuates the stranded requests, and re-places
them on survivors where PR 6's replay machinery recovers them. The bar:
**zero lost requests** (every rid terminates completed/shed/rejected/failed),
survivor tokens bitwise identical to the fault-free run across every policy
(``failover_parity_exact``), and immune goodput under failure at least each
baseline's. ``recovery_ticks`` (first death -> last re-placed completion)
tracks how fast the fleet re-absorbs the lost capacity.

**Durability comparison** — ``run_durability`` cuts power to the *whole
fleet* mid-trace (``poweroff`` fleet fault) and recovers from nothing but the
write-ahead journal + newest warm snapshot (``serve/durability.py``), every
policy x replica count against a fault-free immune reference. The bars:
zero lost rids, zero duplicated completions (exactly-once via journal
dedup), every completion bitwise identical to the uninterrupted run
(``durability_parity_exact``), and a warm-snapshot restart re-prefilling at
most 0.5x the tokens of a journal-only cold restart at an equal page budget.

Latencies are in engine *ticks* (one decode step for the whole slot pool), so
results are deterministic and hardware-independent. Results go to a CSV and to
a machine-readable ``BENCH_serve.json`` (see benchmarks/README.md) so the perf
trajectory is tracked across PRs; CI uploads the JSON as a workflow artifact.

    PYTHONPATH=src python -m benchmarks.serve_engine [--smoke] [--seeds 0 1 2] \
        [--json BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Optional

import jax
import numpy as np

from repro import configs
from repro.models import model
from repro.serve import api, traces
from repro.serve import decode as decode_mod
from repro.serve import engine as eng_mod

ENGINES = {
    # layout -> EngineConfig overrides (equal usable KV tokens both ways)
    "fixed": dict(slots_factor=1, page_size=None, prefill_chunk=0),
    "paged": dict(slots_factor=2, page_size=16, prefill_chunk=16),
}


def _ecfg(layout: str, policy: str, budget_slots: int, max_cache: int,
          latency_budget: float) -> eng_mod.EngineConfig:
    spec = ENGINES[layout]
    page = spec["page_size"] or max_cache
    budget_pages = budget_slots * max_cache // page      # usable pages
    return eng_mod.EngineConfig(
        num_slots=budget_slots * spec["slots_factor"],
        max_cache=max_cache,
        policy=policy,
        num_classes=3,
        latency_budget=latency_budget,
        page_size=page,
        num_pages=budget_pages + 1,                      # + the null page
        prefill_chunk=spec["prefill_chunk"],
    )


def run(arch: str = "smollm-360m", num_requests: int = 40, budget_slots: int = 4,
        max_cache: int = 64, latency_budget: float = 32.0,
        seeds: tuple = (0, 1, 2),
        out_csv: str = "benchmarks/results/serve_engine.csv",
        out_json: Optional[str] = "BENCH_serve.json") -> dict:
    cfg = configs.get_config(arch).smoke()
    params = model.init_params(jax.random.PRNGKey(0), cfg)

    rows = []
    for seed in seeds:
        for layout in ("fixed", "paged"):
            for policy in ("fifo", "immune"):
                ecfg = _ecfg(layout, policy, budget_slots, max_cache,
                             latency_budget)
                # heavy class: long prompt (chunked prefill) + a decode that
                # alone blows the latency budget; 24 + 28 = 52 tokens -> a
                # whole fixed row but only ceil(52/16) = 4 fine pages
                trace = traces.synthetic_trace(
                    cfg, num_requests=num_requests, seed=seed,
                    heavy_prompt=24, heavy_tokens=28)
                eng = eng_mod.Engine(params, cfg, ecfg)
                s = eng.run(trace, max_ticks=50 * num_requests)
                s.update(seed=seed, engine=layout,
                         num_slots=ecfg.num_slots, max_cache=max_cache)
                rows.append(s)
        by = {(r["engine"], r["policy"]): r for r in rows if r["seed"] == seed}
        p, f = by[("paged", "immune")], by[("fixed", "immune")]
        print(f"seed {seed}: paged+chunked p99 {p['p99_latency']:.1f} vs fixed "
              f"{f['p99_latency']:.1f} ticks | concurrency {p['concurrency_hw']}"
              f" vs {f['concurrency_hw']} | pages hw {p['pages_hw']}x"
              f"{p['page_size']} = {p['pages_hw'] * p['page_size']} tokens "
              f"(budget {budget_slots * max_cache}) | goodput "
              f"{p['goodput']:.2f} vs {f['goodput']:.2f}")

    def mean(engine, policy, key):
        vals = [r[key] for r in rows
                if r["engine"] == engine and r["policy"] == policy]
        return float(np.mean(vals))

    pages_hw_tokens = max(r["pages_hw"] * r["page_size"] for r in rows
                          if r["engine"] == "paged")
    summary = {
        "budget_tokens": budget_slots * max_cache,
        "paged_immune_p99": mean("paged", "immune", "p99_latency"),
        "fixed_immune_p99": mean("fixed", "immune", "p99_latency"),
        "paged_immune_goodput": mean("paged", "immune", "goodput"),
        "fixed_immune_goodput": mean("fixed", "immune", "goodput"),
        "paged_concurrency_hw": mean("paged", "immune", "concurrency_hw"),
        "fixed_concurrency_hw": mean("fixed", "immune", "concurrency_hw"),
        "paged_pages_hw_tokens_max": pages_hw_tokens,
        "checks": {},
    }
    summary["checks"] = {
        # the acceptance bar, machine-checkable across PRs
        "admits_more_concurrent": summary["paged_concurrency_hw"]
        > summary["fixed_concurrency_hw"],
        "p99_no_worse_than_fixed_immune": summary["paged_immune_p99"]
        <= summary["fixed_immune_p99"],
        # memory actually touched stays below what fixed rows would have to
        # reserve to reach the concurrency the paged engine measured — the
        # packing claim itself, and falsifiable (equality = packing gained
        # nothing over worst-case rows)
        "pages_hw_below_slots_x_max_cache": pages_hw_tokens
        < summary["paged_concurrency_hw"] * max_cache,
    }

    result = {
        "bench": "serve_engine",
        "arch": arch,
        "num_requests": num_requests,
        "seeds": list(seeds),
        "latency_budget": latency_budget,
        "engines": {k: dict(v) for k, v in ENGINES.items()},
        "rows": rows,
        "summary": summary,
    }
    os.makedirs(os.path.dirname(out_csv), exist_ok=True)
    cols = ("seed", "engine", "policy", "throughput", "p50_latency",
            "p99_latency", "goodput", "completed", "shed", "rejected",
            "concurrency_hw", "pages_hw", "page_size")
    with open(out_csv, "w") as fh:
        fh.write(",".join(cols) + "\n")
        for r in rows:
            fh.write(",".join(f"{r[c]:.3f}" if isinstance(r[c], float)
                              else str(r[c]) for c in cols) + "\n")
    if out_json is not None:
        with open(out_json, "w") as fh:
            json.dump(result, fh, indent=1)
    return result


def run_prefix(arch: str = "smollm-360m", num_requests: int = 28,
               num_slots: int = 10, max_cache: int = 64, page_size: int = 16,
               budget_pages: int = 12, seeds: tuple = (0, 1),
               parity_requests: int = 8) -> dict:
    """Prefix sharing on vs off on system-prompt traffic at an identical tight
    page budget. Sharing admits deeper (only unshared pages are charged), so
    the on-engine should sustain materially more concurrent slots — and its
    tokens must stay bitwise one-shot-exact.

    Runs under ``admission_mode="reserve"``: this A/B isolates what sharing
    buys the *reservation* discipline (fewer pages charged at admit). Under
    the preempt default both sides saturate the budget regardless, and the
    sharing win moves to skipped prefill / pinned adoption — measured by the
    ``pinning`` section instead."""
    cfg = configs.get_config(arch).smoke()
    params = model.init_params(jax.random.PRNGKey(0), cfg)

    rows = []
    parity_exact = True
    for seed in seeds:
        for share in (False, True):
            ecfg = eng_mod.EngineConfig(
                num_slots=num_slots, max_cache=max_cache, policy="fifo",
                page_size=page_size, num_pages=budget_pages + 1,
                prefill_chunk=page_size, prefill_streams=2,
                prefix_sharing=share, admission_mode="reserve")
            trace = traces.shared_prefix_trace(
                cfg, num_requests=num_requests, num_prefixes=2, prefix_len=32,
                suffix_lens=(4, 8), decode_lens=(6, 10), arrival_every=1,
                seed=seed)
            eng = eng_mod.Engine(params, cfg, ecfg)
            s = eng.run(trace, max_ticks=50 * num_requests)
            s.update(seed=seed, engine="share_on" if share else "share_off")
            rows.append(s)
            if share and seed == seeds[0]:
                for req in eng.completed[:parity_requests]:
                    toks, _ = decode_mod.generate(
                        params, cfg, req.prompts(), max_cache=max_cache,
                        steps=req.max_new_tokens)
                    if req.out_tokens != [int(t) for t in np.asarray(toks[0])]:
                        parity_exact = False
        by = {r["engine"]: r for r in rows if r["seed"] == seed}
        on, off = by["share_on"], by["share_off"]
        print(f"seed {seed}: share-on concurrency {on['concurrency_hw']} vs "
              f"{off['concurrency_hw']} | p99 {on['p99_latency']:.1f} vs "
              f"{off['p99_latency']:.1f} ticks | pages hw {on['pages_hw']} vs "
              f"{off['pages_hw']} of {budget_pages} | hit rate "
              f"{on['prefix_hit_rate']:.2f} | {on['cow_forks']} CoW forks | "
              f"{on['prefill_positions_skipped']} prefill positions skipped")

    def mean(engine, key):
        return float(np.mean([r[key] for r in rows if r["engine"] == engine]))

    summary = {
        "budget_pages": budget_pages,
        "share_on_p99": mean("share_on", "p99_latency"),
        "share_off_p99": mean("share_off", "p99_latency"),
        "share_on_concurrency_hw": mean("share_on", "concurrency_hw"),
        "share_off_concurrency_hw": mean("share_off", "concurrency_hw"),
        "share_on_pages_hw": mean("share_on", "pages_hw"),
        "share_off_pages_hw": mean("share_off", "pages_hw"),
        "prefix_hit_rate": mean("share_on", "prefix_hit_rate"),
        "cow_forks": mean("share_on", "cow_forks"),
        "prefill_positions_skipped": mean("share_on",
                                          "prefill_positions_skipped"),
        "share_parity_exact": parity_exact,
    }
    summary["checks"] = {
        # the acceptance bar: at equal page budget, sharing sustains >= 1.5x
        # the concurrency OR >= 30% lower pages high-water — and is exact
        "sharing_concurrency_or_pages_win":
            summary["share_on_concurrency_hw"]
            >= 1.5 * summary["share_off_concurrency_hw"]
            or summary["share_on_pages_hw"]
            <= 0.7 * summary["share_off_pages_hw"],
        "share_p99_no_worse": summary["share_on_p99"]
        <= summary["share_off_p99"],
        "share_parity_exact": parity_exact,
    }
    return {"rows": rows, "summary": summary}


def run_sampling(arch: str = "smollm-360m", num_requests: int = 20,
                 num_slots: int = 4, max_cache: int = 64,
                 seeds: tuple = (0, 1)) -> dict:
    """Greedy vs seeded-sampled serving on the *same* bursty trace at equal
    occupancy: identical arrivals, prompts, and token budgets, so the two
    runs schedule identically and the only difference is the sampling lane in
    the compiled decode step. The JSON records tick- and wall-clock
    throughput for both, plus ``sampling_parity_exact``: every sampled
    completion replayed through the one-shot ``api.generate`` facade must be
    token-identical (same seed => same stream, either backend)."""
    import time

    cfg = configs.get_config(arch).smoke()
    params = model.init_params(jax.random.PRNGKey(0), cfg)

    rows = []
    parity_exact = True
    for seed in seeds:
        for mode, temp in (("greedy", 0.0), ("sampled", 0.8)):
            ecfg = eng_mod.EngineConfig(num_slots=num_slots,
                                        max_cache=max_cache, policy="fifo",
                                        prefill_chunk=16)
            trace = traces.synthetic_trace(
                cfg, num_requests=num_requests, seed=seed, temperature=temp,
                top_p=0.9, sample_seed=1000 * seed)
            eng = eng_mod.Engine(params, cfg, ecfg)
            t0 = time.perf_counter()
            s = eng.run(trace, max_ticks=50 * num_requests)
            dt = time.perf_counter() - t0
            s.update(seed=seed, engine=mode,
                     wall_s=dt, wall_tok_s=s["tokens"] / max(dt, 1e-9))
            rows.append(s)
            if mode == "sampled":        # EVERY sampled completion replays
                for req in eng.completed:
                    eng_toks = list(req.out_tokens)
                    # fresh record, same prompt/params INCLUDING any frontend
                    # inputs (vlm patches / audio frames ride the request)
                    probe = api.ServeRequest(rid=req.rid, tokens=req.tokens,
                                             params=req.params,
                                             patches=req.patches,
                                             frames=req.frames)
                    out = api.generate(params, cfg, probe,
                                       max_cache=max_cache)
                    if out.tokens != eng_toks:
                        parity_exact = False
        by = {r["engine"]: r for r in rows if r["seed"] == seed}
        g, sm = by["greedy"], by["sampled"]
        print(f"seed {seed}: sampled {sm['throughput']:.2f} tok/tick "
              f"({sm['wall_tok_s']:.0f} tok/s) vs greedy "
              f"{g['throughput']:.2f} ({g['wall_tok_s']:.0f} tok/s) | "
              f"concurrency {sm['concurrency_hw']} vs {g['concurrency_hw']} | "
              f"{sm['sampled_requests']} sampled requests")

    def mean(engine, key):
        return float(np.mean([r[key] for r in rows if r["engine"] == engine]))

    summary = {
        "greedy_throughput": mean("greedy", "throughput"),
        "sampled_throughput": mean("sampled", "throughput"),
        "greedy_wall_tok_s": mean("greedy", "wall_tok_s"),
        "sampled_wall_tok_s": mean("sampled", "wall_tok_s"),
        "greedy_concurrency_hw": mean("greedy", "concurrency_hw"),
        "sampled_concurrency_hw": mean("sampled", "concurrency_hw"),
        "sampling_parity_exact": parity_exact,
    }
    summary["checks"] = {
        # seeded engine tokens == one-shot facade tokens, bit for bit
        "sampling_parity_exact": parity_exact,
        # both modes served the whole trace...
        "all_completed": all(r["completed"] == num_requests for r in rows),
        # ...at the same occupancy (identical arrivals/budgets => identical
        # scheduling: sampling must not perturb admission or retirement)
        "equal_occupancy": summary["sampled_concurrency_hw"]
        == summary["greedy_concurrency_hw"],
        "tick_throughput_equal": abs(summary["sampled_throughput"]
                                     - summary["greedy_throughput"]) < 1e-9,
    }
    return {"rows": rows, "summary": summary}


def run_spec_decode(arch: str = "smollm-360m", sessions: int = 4,
                    turns: int = 3, spec_k: int = 6, draft_layers: int = 1,
                    num_slots: int = 4, max_cache: int = 96,
                    page_size: int = 16, seeds: tuple = (0, 1)) -> dict:
    """Self-speculative decoding vs plain greedy decode on the agentic
    multi-turn trace at an **equal page budget**: the same engine config twice
    (same slots, pages, chunked prefill), the spec run drafting ``spec_k``
    tokens per tick through the first ``draft_layers`` layer reps and
    verifying them in one batched paged step. Parameters are made
    draft-friendly (``serve.spec.make_draft_friendly``) so a random init
    stands in for the trained-model property that late layers refine rather
    than rewrite — the *accept rate* depends on it, the parity bit does not.

    The JSON records the accept rate (accepted drafts / proposed drafts —
    the bonus token is free either way, so this is the draft head's hit
    rate), tick and wall-clock speedup over non-speculative serving, and
    ``spec_parity_exact``: every completion's token stream must be **bitwise
    identical** between the two runs (greedy accept is an oracle on the
    verify logits, which row-for-row equal sequential decode's). Wall clock
    is measured *warm*: each mode first drives a warm-up trace through a
    throwaway engine (same config, same shape buckets) so compile time —
    identical work either way, but huge relative to the smoke model — does
    not wash the decode-path difference out of the ratio."""
    import time

    from repro.serve import spec as spec_mod

    cfg = configs.get_config(arch).smoke()
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    params = spec_mod.make_draft_friendly(params, cfg, depth=draft_layers)

    def mk_trace(seed):
        # decode-heavy on purpose: speculative ticks compress decode, not
        # prefill, and the agentic trace's grown prefixes keep prompts cheap
        return traces.agentic_trace(
            cfg, sessions=sessions, turns=turns, base_prompt=16,
            grow_lens=(4, 6), decode_lens=(32, 48), seed=seed)

    rows = []
    parity_exact = True
    num_requests = sessions * turns
    for seed in seeds:
        toks_by = {}
        for mode in ("nonspec", "spec"):
            ecfg = eng_mod.EngineConfig(
                num_slots=num_slots, max_cache=max_cache, policy="fifo",
                page_size=page_size,
                num_pages=num_slots * max_cache // page_size + 1,
                prefill_chunk=page_size,
                spec_decode=spec_k if mode == "spec" else 0,
                spec_draft_layers=draft_layers if mode == "spec" else 0)
            warm = eng_mod.Engine(params, cfg, ecfg)     # compile, discard
            warm.run(mk_trace(seed + 7919), max_ticks=50 * num_requests)
            eng = eng_mod.Engine(params, cfg, ecfg)
            t0 = time.perf_counter()
            s = eng.run(mk_trace(seed), max_ticks=50 * num_requests)
            dt = time.perf_counter() - t0
            s.update(seed=seed, engine=mode, wall_s=dt,
                     wall_tok_s=s["tokens"] / max(dt, 1e-9))
            rows.append(s)
            toks_by[mode] = {r.rid: list(r.out_tokens)
                             for r in eng.completed}
        if toks_by["spec"] != toks_by["nonspec"]:
            parity_exact = False
        by = {r["engine"]: r for r in rows if r["seed"] == seed}
        ns, sp = by["nonspec"], by["spec"]
        print(f"seed {seed}: spec {sp['ticks']} ticks "
              f"({sp['wall_tok_s']:.0f} tok/s) vs nonspec {ns['ticks']} "
              f"({ns['wall_tok_s']:.0f} tok/s) | accept rate "
              f"{sp['spec_accept_rate']:.2f} | "
              f"{sp['spec_emitted']} tokens emitted speculatively")

    def mean(engine, key):
        return float(np.mean([r[key] for r in rows if r["engine"] == engine]))

    summary = {
        "spec_k": spec_k,
        "draft_layers": draft_layers,
        "spec_accept_rate": mean("spec", "spec_accept_rate"),
        "spec_ticks": mean("spec", "ticks"),
        "nonspec_ticks": mean("nonspec", "ticks"),
        "tick_speedup": mean("nonspec", "ticks")
        / max(mean("spec", "ticks"), 1e-9),
        "spec_wall_tok_s": mean("spec", "wall_tok_s"),
        "nonspec_wall_tok_s": mean("nonspec", "wall_tok_s"),
        "wall_speedup": mean("spec", "wall_tok_s")
        / max(mean("nonspec", "wall_tok_s"), 1e-9),
        "spec_parity_exact": parity_exact,
    }
    summary["checks"] = {
        # spec-engine tokens == plain-engine tokens, bit for bit
        "spec_parity_exact": parity_exact,
        "all_completed": all(r["completed"] == num_requests for r in rows),
        # the draft head must actually land drafts (draft-friendly params)
        "accept_rate_positive": summary["spec_accept_rate"] > 0.25,
        # deterministic speedup bar: fewer engine ticks for the same tokens
        "tick_speedup_ok": summary["tick_speedup"] >= 1.2,
        # wall-clock bar on the agentic trace at equal page budget
        "wall_speedup_ok": summary["wall_speedup"] >= 1.2,
    }
    return {"rows": rows, "summary": summary}


def run_pinning(arch: str = "smollm-360m", tenants: int = 2,
                prefix_len: int = 48, bursts: int = 2, burst_size: int = 3,
                gap: int = 100, num_slots: int = 3, max_cache: int = 64,
                page_size: int = 16, pin_budget: int = 8,
                seeds: tuple = (0, 1)) -> dict:
    """Pinned prefix cache on vs off at an *equal* page budget on
    returning-tenant traffic (bursts separated by full drains). With
    ``pin_pages == 0`` every burst re-prefills each tenant's prefix from
    scratch (refcounts hit zero in the gap); with a pin budget the later
    bursts adopt the tenant's pinned chain and prefill only suffixes. The
    acceptance bar: second-and-later-burst prefill tokens with pinning at most
    0.3x pinning-off — and every pinned-adopt completion replays bitwise
    through one-shot ``decode.generate``."""
    cfg = configs.get_config(arch).smoke()
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    budget_pages = num_slots * (max_cache // page_size)

    rows = []
    parity_exact = True
    for seed in seeds:
        for pin in (0, pin_budget):
            ecfg = eng_mod.EngineConfig(
                num_slots=num_slots, max_cache=max_cache, policy="fifo",
                num_classes=tenants, page_size=page_size,
                num_pages=budget_pages + 1, prefill_chunk=8,
                pin_pages=pin)
            trace = traces.returning_tenant_trace(
                cfg, tenants=tenants, prefix_len=prefix_len,
                burst_size=burst_size, bursts=bursts, gap=gap, seed=seed)
            eng = eng_mod.Engine(params, cfg, ecfg)
            s = eng.run(trace, max_ticks=gap * bursts + 200)
            # burst 1 is identical in both runs (cold cache); the cache's win
            # is everything after the first drain
            s["later_burst_prefill_tokens"] = sum(
                r.prefill_tokens for r in eng.completed if r.arrival >= gap)
            s.update(seed=seed, engine="pin_on" if pin else "pin_off")
            rows.append(s)
            if pin and seed == seeds[0]:     # pinned-adopt parity, bit for bit
                for req in eng.completed:
                    toks, _ = decode_mod.generate(
                        params, cfg, req.prompts(), max_cache=max_cache,
                        steps=req.max_new_tokens)
                    if req.out_tokens != [int(t) for t in np.asarray(toks[0])]:
                        parity_exact = False
        by = {r["engine"]: r for r in rows if r["seed"] == seed}
        on, off = by["pin_on"], by["pin_off"]
        print(f"seed {seed}: later-burst prefill {on['later_burst_prefill_tokens']}"
              f" tokens pinned vs {off['later_burst_prefill_tokens']} unpinned | "
              f"{on['pinned_pages_adopted']} pinned pages adopted | hit rate "
              f"{on['pinned_hit_rate']:.2f} | {on['pins']} pins / "
              f"{on['pin_evictions']} evictions | p99 {on['p99_latency']:.1f} "
              f"vs {off['p99_latency']:.1f} ticks")

    def mean(engine, key):
        return float(np.mean([r[key] for r in rows if r["engine"] == engine]))

    summary = {
        "budget_pages": budget_pages,
        "pin_budget": pin_budget,
        "pin_on_later_prefill_tokens": mean("pin_on",
                                            "later_burst_prefill_tokens"),
        "pin_off_later_prefill_tokens": mean("pin_off",
                                             "later_burst_prefill_tokens"),
        "pinned_pages_adopted": mean("pin_on", "pinned_pages_adopted"),
        "pinned_hit_rate": mean("pin_on", "pinned_hit_rate"),
        "pin_on_p99": mean("pin_on", "p99_latency"),
        "pin_off_p99": mean("pin_off", "p99_latency"),
        "pin_parity_exact": parity_exact,
    }
    summary["checks"] = {
        # the acceptance bar: a returning tenant's later bursts cost <= 0.3x
        # the prefill tokens of the cold-cache engine at the same page budget
        "pinned_prefill_at_most_0.3x": summary["pin_on_later_prefill_tokens"]
        <= 0.3 * summary["pin_off_later_prefill_tokens"],
        "pinned_pages_actually_adopted": summary["pinned_pages_adopted"] > 0,
        "pin_p99_no_worse": summary["pin_on_p99"] <= summary["pin_off_p99"],
        "pin_parity_exact": parity_exact,
        "all_completed": all(r["completed"] == tenants * burst_size * bursts
                             for r in rows),
    }
    return {"rows": rows, "summary": summary}


def run_preemption(arch: str = "smollm-360m", num_requests: int = 24,
                   num_slots: int = 4, max_cache: int = 64,
                   page_size: int = 16, budget_pages: int = 6,
                   seeds: tuple = (0, 1)) -> dict:
    """Worst-case reservation vs immune-priority preemption on the *same*
    contention trace at the *same* undersized page budget. Reservation admits
    on each request's worst case (prompt + full decode budget), so the pool's
    promise capacity gates concurrency; preemption admits on current footprint
    and resolves decode-time exhaustion by evicting the lowest-priority slot
    (replayed later, bitwise). The acceptance bar: preemption admits strictly
    deeper and holds a no-worse p99 — and every preempted-then-resumed
    completion is token-identical to its one-shot replay."""
    cfg = configs.get_config(arch).smoke()
    params = model.init_params(jax.random.PRNGKey(0), cfg)

    rows = []
    parity_exact = True
    for seed in seeds:
        for mode in ("reserve", "preempt"):
            ecfg = eng_mod.EngineConfig(
                num_slots=num_slots, max_cache=max_cache, policy="immune",
                num_classes=3, latency_budget=64.0, page_size=page_size,
                num_pages=budget_pages + 1, prefill_chunk=16,
                admission_mode=mode)
            trace = traces.contention_trace(cfg, num_requests=num_requests,
                                            seed=seed)
            eng = eng_mod.Engine(params, cfg, ecfg)
            s = eng.run(trace, max_ticks=50 * num_requests)
            s.update(seed=seed, engine=mode)
            rows.append(s)
            if mode == "preempt" and seed == seeds[0]:
                # EVERY preempted-then-resumed completion replays bitwise
                for req in eng.completed:
                    if req.preemptions == 0:
                        continue
                    probe = api.ServeRequest(rid=req.rid, tokens=req.tokens,
                                             params=req.params)
                    out = api.generate(params, cfg, probe, max_cache=max_cache)
                    if out.tokens != list(req.out_tokens):
                        parity_exact = False
        by = {r["engine"]: r for r in rows if r["seed"] == seed}
        p, r_ = by["preempt"], by["reserve"]
        print(f"seed {seed}: preempt concurrency {p['concurrency_hw']} vs "
              f"reserve {r_['concurrency_hw']} | p99 {p['p99_latency']:.1f} vs "
              f"{r_['p99_latency']:.1f} ticks | {p['preemptions']} preemptions "
              f"over {p['preempted_requests']} requests | "
              f"{p['replayed_tokens']} tokens replayed | completed "
              f"{p['completed']}+{p['shed']}s vs {r_['completed']}+{r_['shed']}s")

    def mean(engine, key):
        return float(np.mean([r[key] for r in rows if r["engine"] == engine]))

    summary = {
        "budget_pages": budget_pages,
        "preempt_concurrency_hw": mean("preempt", "concurrency_hw"),
        "reserve_concurrency_hw": mean("reserve", "concurrency_hw"),
        "preempt_p99": mean("preempt", "p99_latency"),
        "reserve_p99": mean("reserve", "p99_latency"),
        "preempt_goodput": mean("preempt", "goodput"),
        "reserve_goodput": mean("reserve", "goodput"),
        "preemptions": mean("preempt", "preemptions"),
        "replayed_tokens": mean("preempt", "replayed_tokens"),
        "preempt_parity_exact": parity_exact,
    }
    summary["checks"] = {
        # the acceptance bar: strictly deeper admission at the same budget...
        "preempt_admits_strictly_deeper": summary["preempt_concurrency_hw"]
        > summary["reserve_concurrency_hw"],
        # ...with a no-worse tail
        "preempt_p99_no_worse": summary["preempt_p99"]
        <= summary["reserve_p99"],
        # the machinery was actually exercised, not vacuously green
        "preemptions_exercised": summary["preemptions"] > 0,
        "preempt_parity_exact": parity_exact,
    }
    return {"rows": rows, "summary": summary}


def run_routing(arch: str = "smollm-360m", replicas: int = 2,
                num_requests: int = 24, tenants: int = 3,
                prefix_len: int = 32, num_slots: int = 2, max_cache: int = 64,
                page_size: int = 16, pin_pages: int = 4,
                seeds: tuple = (0, 1)) -> dict:
    """Placement-policy A/B over ``replicas`` engine replicas on the
    multi-tenant fleet trace (tenant-keyed prompts, bursty arrivals, one hot
    tenant), every policy at the *same* replica count and per-replica page/pin
    budget. Round-robin and join-shortest-queue are the taxonomy baselines;
    the immune router places by prefix affinity -> anergy draining -> least
    remembered cost, so a tenant's traffic stays where its pinned chains live
    and the fleet prefills only suffixes. The bar: immune p99 at most the best
    baseline's, affinity hits actually taken, and per-request tokens bitwise
    identical across every (policy, replica-count) run — placement decides
    where a request runs, never what it computes (``routing_parity_exact``;
    an immune single-replica run rides along to pin the replica-count axis)."""
    from repro.serve import router as rt_mod

    cfg = configs.get_config(arch).smoke()
    params = model.init_params(jax.random.PRNGKey(0), cfg)

    def _replica_cfg():
        return eng_mod.EngineConfig(
            num_slots=num_slots, max_cache=max_cache, policy="immune",
            num_classes=tenants, latency_budget=64.0, page_size=page_size,
            num_pages=num_slots * (max_cache // page_size) + 1,
            prefill_chunk=16, pin_pages=pin_pages)

    rows = []
    parity_exact = True
    for seed in seeds:
        tokens_by_rid: dict = {}            # parity across runs at this seed
        for policy in ("rr", "jsq", "immune"):
            for n in ((replicas, 1) if policy == "immune" else (replicas,)):
                router = rt_mod.Router(
                    [eng_mod.Engine(params, cfg, _replica_cfg())
                     for _ in range(n)],
                    rt_mod.RouterConfig(policy=policy))
                # fresh trace per run: serving mutates the requests
                trace = traces.fleet_trace(cfg, tenants=tenants,
                                           num_requests=num_requests,
                                           prefix_len=prefix_len, seed=seed)
                s = router.run(trace, max_ticks=50 * num_requests)
                del s["per_replica"]        # keep the JSON rows flat
                s.update(seed=seed, engine=f"{policy}_x{n}")
                rows.append(s)
                for req in router.completed:
                    ref = tokens_by_rid.setdefault(req.rid,
                                                   list(req.out_tokens))
                    if ref != list(req.out_tokens):
                        parity_exact = False
        by = {r["engine"]: r for r in rows if r["seed"] == seed}
        im, rr_, jq = (by[f"{p}_x{replicas}"] for p in ("immune", "rr", "jsq"))
        print(f"seed {seed}: immune p99 {im['p99_latency']:.1f} vs rr "
              f"{rr_['p99_latency']:.1f} / jsq {jq['p99_latency']:.1f} ticks | "
              f"affinity {im['affinity_hits']}/{im['affinity_checks']} "
              f"({im['affinity_tokens']} resident tokens) | prefill "
              f"{im['prefill_tokens']} vs {rr_['prefill_tokens']} / "
              f"{jq['prefill_tokens']} tokens | placements {im['placements']} "
              f"| drains {im['drain_skips']}")

    def mean(engine, key):
        return float(np.mean([r[key] for r in rows if r["engine"] == engine]))

    lab = f"_x{replicas}"
    summary = {
        "replicas": replicas,
        "pages_per_replica": num_slots * (max_cache // page_size),
        "pin_pages_per_replica": pin_pages,
        "immune_p99": mean("immune" + lab, "p99_latency"),
        "rr_p99": mean("rr" + lab, "p99_latency"),
        "jsq_p99": mean("jsq" + lab, "p99_latency"),
        "immune_goodput": mean("immune" + lab, "goodput"),
        "rr_goodput": mean("rr" + lab, "goodput"),
        "jsq_goodput": mean("jsq" + lab, "goodput"),
        "affinity_hit_rate": mean("immune" + lab, "affinity_hit_rate"),
        "affinity_tokens": mean("immune" + lab, "affinity_tokens"),
        "immune_prefill_tokens": mean("immune" + lab, "prefill_tokens"),
        "rr_prefill_tokens": mean("rr" + lab, "prefill_tokens"),
        "jsq_prefill_tokens": mean("jsq" + lab, "prefill_tokens"),
        "placement_imbalance": mean("immune" + lab, "placement_imbalance"),
        "routing_parity_exact": parity_exact,
    }
    summary["checks"] = {
        # the acceptance bar: immune placement holds the best baseline's tail
        "immune_p99_no_worse_than_baselines": summary["immune_p99"]
        <= min(summary["rr_p99"], summary["jsq_p99"]),
        # the affinity signal was actually exercised, not vacuously green
        "affinity_hits_positive": summary["affinity_hit_rate"] > 0,
        # affinity placements skip prefix prefill the baselines re-pay
        "immune_prefills_least": summary["immune_prefill_tokens"]
        <= min(summary["rr_prefill_tokens"], summary["jsq_prefill_tokens"]),
        "routing_parity_exact": parity_exact,
        "all_completed": all(r["completed"] == num_requests
                             and r["shed"] == 0 and r["unserved"] == 0
                             for r in rows),
    }
    return {"rows": rows, "summary": summary}


def run_failover(arch: str = "smollm-360m", replicas: int = 3,
                 num_requests: int = 24, tenants: int = 3,
                 prefix_len: int = 32, num_slots: int = 2, max_cache: int = 64,
                 page_size: int = 16, pin_pages: int = 4,
                 seeds: tuple = (0, 1)) -> dict:
    """Crash-of-1-of-``replicas`` + cold rejoin on the fleet trace, every
    policy against the same seeded fault plan, plus one fault-free immune
    reference per seed. The health machine must detect the death (never
    announced), evacuate and re-place the stranded requests, and recover
    them bitwise (``failover_parity_exact`` vs the fault-free run); zero
    requests may be lost — each rid terminates completed, shed, rejected, or
    ``failed`` — and immune goodput under failure must hold at least the
    rr/jsq baselines' (graceful degradation is an operator opt-in and stays
    off here so the A/B compares like with like)."""
    from repro.serve import router as rt_mod
    from repro.serve.faults import FaultInjector, FaultPlan

    cfg = configs.get_config(arch).smoke()
    params = model.init_params(jax.random.PRNGKey(0), cfg)

    def _replica_cfg():
        return eng_mod.EngineConfig(
            num_slots=num_slots, max_cache=max_cache, policy="immune",
            num_classes=tenants, latency_budget=64.0, page_size=page_size,
            num_pages=num_slots * (max_cache // page_size) + 1,
            prefill_chunk=16, pin_pages=pin_pages)

    def _mk(seed):
        return traces.failover_fleet_trace(
            cfg, replicas=replicas, num_requests=num_requests,
            tenants=tenants, prefix_len=prefix_len, seed=seed)

    rows = []
    parity_exact = True
    zero_lost = True
    recovered = True
    for seed in seeds:
        tokens_by_rid: dict = {}         # fault-free reference, then survivors
        reqs, spec = _mk(seed)
        clean = rt_mod.Router(
            [eng_mod.Engine(params, cfg, _replica_cfg())
             for _ in range(replicas)],
            rt_mod.RouterConfig(policy="immune"))
        s = clean.run(reqs, max_ticks=50 * num_requests)
        del s["per_replica"]
        s.update(seed=seed, engine="immune_clean", plan="")
        rows.append(s)
        for req in clean.completed:
            tokens_by_rid[req.rid] = list(req.out_tokens)
        for policy in ("rr", "jsq", "immune"):
            reqs, spec = _mk(seed)       # fresh trace: serving mutates it
            router = rt_mod.Router(
                [eng_mod.Engine(params, cfg, _replica_cfg())
                 for _ in range(replicas)],
                rt_mod.RouterConfig(policy=policy),
                injector=FaultInjector(
                    FaultPlan.parse(spec),
                    engine_factory=lambda: eng_mod.Engine(params, cfg,
                                                          _replica_cfg())))
            s = router.run(reqs, max_ticks=50 * num_requests)
            del s["per_replica"]
            s.update(seed=seed, engine=f"{policy}_fault", plan=spec)
            rows.append(s)
            for req in router.completed:   # survivors vs the fault-free run
                ref = tokens_by_rid.setdefault(req.rid, list(req.out_tokens))
                if ref != list(req.out_tokens):
                    parity_exact = False
            if s["completed"] + s["shed"] + s["rejected"] + s["failed"] \
                    != num_requests or s["unserved"] != 0:
                zero_lost = False
            if not (s["deaths"] == 1 and s["rejoins"] == 1
                    and s["replaced_requests"] > 0
                    and s["recovery_ticks"] > 0):
                recovered = False
        by = {r["engine"]: r for r in rows if r["seed"] == seed}
        im, cl = by["immune_fault"], by["immune_clean"]
        print(f"seed {seed}: plan '{im['plan']}' | immune goodput under crash "
              f"{im['goodput']:.2f} (clean {cl['goodput']:.2f}) vs rr "
              f"{by['rr_fault']['goodput']:.2f} / jsq "
              f"{by['jsq_fault']['goodput']:.2f} | p99 {im['p99_latency']:.1f}"
              f" vs clean {cl['p99_latency']:.1f} ticks | "
              f"{im['replaced_requests']} re-placed, {im['failed']} failed | "
              f"recovery {im['recovery_ticks']} ticks")

    def mean(engine, key):
        return float(np.mean([r[key] for r in rows if r["engine"] == engine]))

    summary = {
        "replicas": replicas,
        "immune_goodput": mean("immune_fault", "goodput"),
        "rr_goodput": mean("rr_fault", "goodput"),
        "jsq_goodput": mean("jsq_fault", "goodput"),
        "clean_goodput": mean("immune_clean", "goodput"),
        "immune_p99": mean("immune_fault", "p99_latency"),
        "rr_p99": mean("rr_fault", "p99_latency"),
        "jsq_p99": mean("jsq_fault", "p99_latency"),
        "clean_p99": mean("immune_clean", "p99_latency"),
        "recovery_ticks": mean("immune_fault", "recovery_ticks"),
        "replaced_requests": mean("immune_fault", "replaced_requests"),
        "failed_requests": mean("immune_fault", "failed"),
        "failover_parity_exact": parity_exact,
    }
    summary["checks"] = {
        # the acceptance bar: a crash moves work, it never changes tokens...
        "failover_parity_exact": parity_exact,
        # ...or loses a request: every rid terminates in an accounted bucket
        "zero_lost_requests": zero_lost,
        # the fault actually bit and the fleet actually recovered (death
        # detected, requests re-placed, rejoin landed) — not vacuously green
        "failover_exercised": recovered,
        # immune placement degrades no worse than the taxonomy baselines
        "immune_goodput_under_failure_no_worse": summary["immune_goodput"]
        >= max(summary["rr_goodput"], summary["jsq_goodput"]),
    }
    return {"rows": rows, "summary": summary}


def run_durability(arch: str = "smollm-360m", num_requests: int = 24,
                   tenants: int = 2, prefix_len: int = 64, num_slots: int = 2,
                   max_cache: int = 96, page_size: int = 16,
                   pin_pages: int = 8, replica_counts: tuple = (2, 3),
                   seeds: tuple = (0, 1)) -> dict:
    """Full-fleet power loss mid-trace + journal/snapshot recovery
    (``serve/durability.py``), every policy x replica count against the same
    fault-free immune reference. The WAL is group-committed, the power loss
    truncates it to the last fsync'd byte, and ``run_durable`` rebuilds a
    fresh fleet from nothing but the journal + newest warm snapshot. The
    bars: the interrupted trace completes with **zero lost rids and zero
    duplicated completions** (exactly-once via journal dedup), every
    completion's tokens **bitwise identical** to the uninterrupted run
    (``durability_parity_exact``), and a warm-snapshot restart — the pinned
    prefix forest's K/V restored, zero recompute — re-prefills at most
    **0.5x** the tokens of a journal-only cold restart at an equal page
    budget. The trace is prefix-dominated (long shared system prompts, short
    suffixes) and the plan cuts power after the arrival horizon, so recovery
    replays a full backlog — the regime the snapshot exists for."""
    import shutil
    import tempfile

    from repro.serve import durability
    from repro.serve import router as rt_mod
    from repro.serve.faults import FaultInjector, FaultPlan

    cfg = configs.get_config(arch).smoke()
    params = model.init_params(jax.random.PRNGKey(0), cfg)

    def _replica_cfg():
        return eng_mod.EngineConfig(
            num_slots=num_slots, max_cache=max_cache, policy="immune",
            num_classes=tenants, latency_budget=50.0 * num_requests,
            page_size=page_size, prefill_chunk=16, pin_pages=pin_pages,
            num_pages=num_slots * (max_cache // page_size) + 1 + pin_pages)

    def _mk(seed):
        return traces.fleet_trace(
            cfg, tenants=tenants, num_requests=num_requests,
            prefix_len=prefix_len, suffix_lens=(4,), decode_lens=(8,),
            hot_frac=0.9, burst_every=2, burst_size=4, seed=seed)

    def _factory(replicas, policy, spec):
        def make():
            return rt_mod.Router(
                [eng_mod.Engine(params, cfg, _replica_cfg())
                 for _ in range(replicas)],
                rt_mod.RouterConfig(policy=policy),
                injector=FaultInjector(FaultPlan.parse(spec)))
        return make

    scratch = tempfile.mkdtemp(prefix="bench_durability_")
    rows = []
    parity_exact = True
    zero_lost = True
    zero_dup = True
    exercised = True
    warm_pf, cold_pf = [], []
    try:
        for seed in seeds:
            # fault-free immune reference: the parity oracle for every
            # poweroff run (placement never changes a request's tokens)
            clean = rt_mod.Router(
                [eng_mod.Engine(params, cfg, _replica_cfg())
                 for _ in range(replica_counts[0])],
                rt_mod.RouterConfig(policy="immune"))
            s = clean.run(_mk(seed), max_ticks=50 * num_requests)
            del s["per_replica"]
            s.update(seed=seed, engine="immune_clean", plan="", restarts=0)
            rows.append(s)
            ref = {r.rid: list(r.out_tokens) for r in clean.completed}
            # cut power after the arrival horizon: the whole backlog is
            # journaled and must replay through recovery
            horizon = max(r.arrival for r in _mk(seed))
            off = max(horizon + 2, (3 * s["ticks"]) // 5)
            spec = f"poweroff@{off} restart@{off + 4}"
            for replicas in replica_counts:
                for policy in ("rr", "jsq", "immune"):
                    warm = policy == "immune" and replicas == replica_counts[0]
                    d = os.path.join(scratch, f"{seed}_{replicas}_{policy}")
                    router, s = durability.run_durable(
                        _factory(replicas, policy, spec), _mk(seed),
                        os.path.join(d, "journal.wal"),
                        snapshot_dir=os.path.join(d, "snap") if warm
                        else None,
                        snapshot_every=2, max_ticks=50 * num_requests)
                    del s["per_replica"]
                    s.update(seed=seed, engine=f"{policy}_poweroff_r{replicas}",
                             plan=spec, restart_tick=off + 4)
                    rows.append(s)
                    rids = [r.rid for r in router.completed]
                    if len(rids) != len(set(rids)):
                        zero_dup = False
                    for req in router.completed:
                        if ref.get(req.rid, list(req.out_tokens)) \
                                != list(req.out_tokens):
                            parity_exact = False
                    if s["completed"] + s["shed"] + s["rejected"] \
                            + s["corrupted"] + s["failed"] != num_requests \
                            or s["unserved"] != 0:
                        zero_lost = False
                    dur = s["durability"]
                    if not (s["restarts"] == 1
                            and dur["recovered_finished"]
                            + dur["recovered_open"] > 0):
                        exercised = False
                    if warm:
                        warm_pf.append(sum(e.prefill_tokens
                                           for e in router.engines))
                        if dur["recovered_pinned_pages"] <= 0:
                            exercised = False
            # journal-only cold restart at the same page budget: the
            # warm-vs-cold A/B for this seed's snapshot
            d = os.path.join(scratch, f"{seed}_cold")
            router, s = durability.run_durable(
                _factory(replica_counts[0], "immune", spec), _mk(seed),
                os.path.join(d, "journal.wal"), max_ticks=50 * num_requests)
            cold_pf.append(sum(e.prefill_tokens for e in router.engines))
            for req in router.completed:
                if ref.get(req.rid, list(req.out_tokens)) \
                        != list(req.out_tokens):
                    parity_exact = False
            im = next(r for r in rows
                      if r["seed"] == seed and r["engine"]
                      == f"immune_poweroff_r{replica_counts[0]}")
            print(f"seed {seed}: plan '{spec}' | immune survived "
                  f"{im['restarts']} poweroff: {im['completed']} done, "
                  f"{im['durability']['recovered_finished']} deduped + "
                  f"{im['durability']['recovered_open']} replayed, "
                  f"{im['durability']['recovered_pinned_pages']} pages warm | "
                  f"post-restart prefill warm {warm_pf[-1]} vs cold "
                  f"{cold_pf[-1]} tokens")
    finally:
        shutil.rmtree(scratch, ignore_errors=True)

    def mean(pred, key):
        vs = [r[key] for r in rows if pred(r["engine"])]
        return float(np.mean(vs)) if vs else 0.0

    off_runs = [r for r in rows if "poweroff" in r["engine"]]
    ratio = float(np.sum(warm_pf) / max(np.sum(cold_pf), 1))
    summary = {
        "replica_counts": list(replica_counts),
        # restart tick -> last tick: how long draining the journaled backlog
        # took after the lights came back on
        "recovery_ticks": float(np.mean(
            [r["ticks"] - r["restart_tick"] for r in off_runs])),
        "replayed_tokens": mean(lambda e: "poweroff" in e, "replayed_tokens"),
        "recovered_finished": float(np.mean(
            [r["durability"]["recovered_finished"] for r in off_runs])),
        "recovered_open": float(np.mean(
            [r["durability"]["recovered_open"] for r in off_runs])),
        "journal_fsyncs": float(np.mean(
            [r["durability"]["journal"]["syncs"] for r in off_runs])),
        "warm_prefill_tokens": float(np.mean(warm_pf)),
        "cold_prefill_tokens": float(np.mean(cold_pf)),
        "warm_cold_prefill_ratio": ratio,
        "poweroff_goodput": mean(lambda e: e.startswith("immune_poweroff"),
                                 "goodput"),
        "clean_goodput": mean(lambda e: e == "immune_clean", "goodput"),
        "durability_parity_exact": parity_exact,
    }
    summary["checks"] = {
        # the acceptance bar: a power loss delays tokens, never changes them
        "durability_parity_exact": parity_exact,
        # exactly-once: no rid lost, no completion duplicated
        "zero_lost_requests": zero_lost,
        "zero_duplicated_completions": zero_dup,
        # the fault actually bit: a restart happened, the journal replayed,
        # and the warm runs restored pinned pages — not vacuously green
        "poweroff_exercised": exercised,
        # the snapshot earns its bytes: warm restart re-prefills at most
        # half of what the journal-only cold restart recomputes
        "warm_restart_halves_prefill": ratio <= 0.5,
    }
    return {"rows": rows, "summary": summary}


def main():
    jax.config.update("jax_platform_name", "cpu")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m",
                    choices=sorted(configs.ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="small trace for CI-class machines")
    ap.add_argument("--seeds", type=int, nargs="+", default=[0, 1, 2])
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="machine-readable results path")
    args = ap.parse_args()

    n = 24 if args.smoke else 40
    res = run(arch=args.arch, num_requests=n, seeds=tuple(args.seeds),
              out_json=None)                  # single JSON write, below
    res["prefix_sharing"] = run_prefix(
        arch=args.arch, num_requests=16 if args.smoke else 28,
        seeds=tuple(args.seeds)[:2])
    res["sampling"] = run_sampling(
        arch=args.arch, num_requests=12 if args.smoke else 20,
        seeds=tuple(args.seeds)[:2])
    res["spec_decode"] = run_spec_decode(
        arch=args.arch, sessions=3 if args.smoke else 4,
        seeds=tuple(args.seeds)[:1 if args.smoke else 2])
    res["pinning"] = run_pinning(
        arch=args.arch, bursts=2 if args.smoke else 3,
        seeds=tuple(args.seeds)[:1 if args.smoke else 2])
    res["preemption"] = run_preemption(
        arch=args.arch, num_requests=16 if args.smoke else 24,
        seeds=tuple(args.seeds)[:1 if args.smoke else 2])
    res["routing"] = run_routing(
        arch=args.arch, num_requests=12 if args.smoke else 24,
        seeds=tuple(args.seeds)[:1 if args.smoke else 2])
    res["failover"] = run_failover(
        arch=args.arch, num_requests=18 if args.smoke else 24,
        seeds=tuple(args.seeds)[:1 if args.smoke else 2])
    res["durability"] = run_durability(
        arch=args.arch, num_requests=18 if args.smoke else 24,
        replica_counts=(2,) if args.smoke else (2, 3),
        seeds=tuple(args.seeds)[:1 if args.smoke else 2])
    with open(args.json, "w") as fh:
        json.dump(res, fh, indent=1)

    s = res["summary"]
    ok = all(s["checks"].values())
    print(f"mean p99: paged+chunked {s['paged_immune_p99']:.1f} vs fixed "
          f"{s['fixed_immune_p99']:.1f} ticks | concurrency "
          f"{s['paged_concurrency_hw']:.1f} vs {s['fixed_concurrency_hw']:.1f}"
          f" | checks {'OK' if ok else 'REGRESSION'}: "
          f"{json.dumps(s['checks'])}")
    p = res["prefix_sharing"]["summary"]
    pok = all(p["checks"].values())
    print(f"prefix sharing: concurrency {p['share_on_concurrency_hw']:.1f} vs "
          f"{p['share_off_concurrency_hw']:.1f} off | pages hw "
          f"{p['share_on_pages_hw']:.1f} vs {p['share_off_pages_hw']:.1f} | "
          f"hit rate {p['prefix_hit_rate']:.2f} | parity "
          f"{'exact' if p['share_parity_exact'] else 'BROKEN'} | checks "
          f"{'OK' if pok else 'REGRESSION'}: {json.dumps(p['checks'])}")
    sm = res["sampling"]["summary"]
    sok = all(sm["checks"].values())
    print(f"sampling: {sm['sampled_throughput']:.2f} tok/tick sampled vs "
          f"{sm['greedy_throughput']:.2f} greedy at equal occupancy "
          f"({sm['sampled_concurrency_hw']:.1f} slots) | "
          f"{sm['sampled_wall_tok_s']:.0f} vs {sm['greedy_wall_tok_s']:.0f} "
          f"tok/s wall | engine-vs-oneshot parity "
          f"{'exact' if sm['sampling_parity_exact'] else 'BROKEN'} | checks "
          f"{'OK' if sok else 'REGRESSION'}: {json.dumps(sm['checks'])}")
    sd = res["spec_decode"]["summary"]
    sdok = all(sd["checks"].values())
    print(f"spec decode: k={sd['spec_k']} depth={sd['draft_layers']} | "
          f"accept rate {sd['spec_accept_rate']:.2f} | "
          f"{sd['spec_ticks']:.0f} vs {sd['nonspec_ticks']:.0f} ticks "
          f"({sd['tick_speedup']:.2f}x) | {sd['spec_wall_tok_s']:.0f} vs "
          f"{sd['nonspec_wall_tok_s']:.0f} tok/s wall "
          f"({sd['wall_speedup']:.2f}x) | parity "
          f"{'exact' if sd['spec_parity_exact'] else 'BROKEN'} | checks "
          f"{'OK' if sdok else 'REGRESSION'}: {json.dumps(sd['checks'])}")
    pn = res["pinning"]["summary"]
    pnok = all(pn["checks"].values())
    print(f"pinning: later-burst prefill "
          f"{pn['pin_on_later_prefill_tokens']:.0f} vs "
          f"{pn['pin_off_later_prefill_tokens']:.0f} tokens "
          f"(ratio {pn['pin_on_later_prefill_tokens'] / max(pn['pin_off_later_prefill_tokens'], 1):.2f})"
          f" | pinned-hit rate {pn['pinned_hit_rate']:.2f} | parity "
          f"{'exact' if pn['pin_parity_exact'] else 'BROKEN'} | checks "
          f"{'OK' if pnok else 'REGRESSION'}: {json.dumps(pn['checks'])}")
    pe = res["preemption"]["summary"]
    peok = all(pe["checks"].values())
    print(f"preemption: concurrency {pe['preempt_concurrency_hw']:.1f} vs "
          f"reserve {pe['reserve_concurrency_hw']:.1f} | p99 "
          f"{pe['preempt_p99']:.1f} vs {pe['reserve_p99']:.1f} ticks | "
          f"{pe['preemptions']:.1f} preemptions | parity "
          f"{'exact' if pe['preempt_parity_exact'] else 'BROKEN'} | checks "
          f"{'OK' if peok else 'REGRESSION'}: {json.dumps(pe['checks'])}")
    rt = res["routing"]["summary"]
    rtok = all(rt["checks"].values())
    print(f"routing: immune p99 {rt['immune_p99']:.1f} vs rr "
          f"{rt['rr_p99']:.1f} / jsq {rt['jsq_p99']:.1f} ticks at "
          f"{rt['replicas']} replicas | affinity hit rate "
          f"{rt['affinity_hit_rate']:.2f} | prefill {rt['immune_prefill_tokens']:.0f}"
          f" vs {rt['rr_prefill_tokens']:.0f} / {rt['jsq_prefill_tokens']:.0f} "
          f"tokens | parity "
          f"{'exact' if rt['routing_parity_exact'] else 'BROKEN'} | checks "
          f"{'OK' if rtok else 'REGRESSION'}: {json.dumps(rt['checks'])}")
    fo = res["failover"]["summary"]
    fook = all(fo["checks"].values())
    print(f"failover: immune goodput under crash {fo['immune_goodput']:.2f} "
          f"(clean {fo['clean_goodput']:.2f}) vs rr {fo['rr_goodput']:.2f} / "
          f"jsq {fo['jsq_goodput']:.2f} | p99 {fo['immune_p99']:.1f} vs clean "
          f"{fo['clean_p99']:.1f} ticks | recovery {fo['recovery_ticks']:.0f} "
          f"ticks over {fo['replaced_requests']:.0f} re-placed | parity "
          f"{'exact' if fo['failover_parity_exact'] else 'BROKEN'} | checks "
          f"{'OK' if fook else 'REGRESSION'}: {json.dumps(fo['checks'])}")
    du = res["durability"]["summary"]
    duok = all(du["checks"].values())
    print(f"durability: poweroff survived at replicas {du['replica_counts']} "
          f"| recovery {du['recovery_ticks']:.0f} ticks, "
          f"{du['replayed_tokens']:.0f} tokens replayed | post-restart "
          f"prefill warm {du['warm_prefill_tokens']:.0f} vs cold "
          f"{du['cold_prefill_tokens']:.0f} tokens "
          f"(ratio {du['warm_cold_prefill_ratio']:.2f}) | goodput "
          f"{du['poweroff_goodput']:.2f} (clean {du['clean_goodput']:.2f}) | "
          f"parity {'exact' if du['durability_parity_exact'] else 'BROKEN'} | "
          f"checks {'OK' if duok else 'REGRESSION'}: "
          f"{json.dumps(du['checks'])}")


if __name__ == "__main__":
    main()
