"""Continuous-batching engine: immune admission vs. FIFO under bursty traffic.

Drives the real engine (smoke-sized model on CPU) over the same synthetic
open-loop arrival trace with both admission policies and compares throughput,
tail latency, and goodput. Traffic is bursty and heterogeneous: mostly light
chat-style requests plus a heavy class whose decode length alone blows the
latency budget — the head-of-line convoy case where FIFO's tail collapses and
the immune loop (remembered cost + anticipatory shedding) protects it.

Latencies are in engine *ticks* (one decode step for the whole slot pool), so
results are deterministic and hardware-independent.

    PYTHONPATH=src python -m benchmarks.serve_engine [--smoke] [--seeds 0 1 2]
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from repro import configs
from repro.models import model
from repro.serve import engine as eng_mod


def run(arch: str = "smollm-360m", num_requests: int = 40, num_slots: int = 4,
        latency_budget: float = 24.0, seeds: tuple = (0, 1, 2),
        out: str = "benchmarks/results/serve_engine.csv"):
    cfg = configs.get_config(arch).smoke()
    params = model.init_params(jax.random.PRNGKey(0), cfg)

    rows = []
    for seed in seeds:
        per_policy = {}
        for policy in ("fifo", "immune"):
            ecfg = eng_mod.EngineConfig(
                num_slots=num_slots, max_cache=64, policy=policy,
                num_classes=3, latency_budget=latency_budget)
            trace = eng_mod.synthetic_trace(cfg, num_requests=num_requests,
                                            seed=seed)
            eng = eng_mod.Engine(params, cfg, ecfg)
            per_policy[policy] = eng.run(trace, max_ticks=50 * num_requests)
        for policy, s in per_policy.items():
            rows.append((seed, policy, s["throughput"], s["p50_latency"],
                         s["p99_latency"], s["goodput"], s["completed"],
                         s["shed"]))
        f, i = per_policy["fifo"], per_policy["immune"]
        print(f"seed {seed}: immune p99 {i['p99_latency']:.1f} vs fifo "
              f"{f['p99_latency']:.1f} ticks | throughput "
              f"{i['throughput']:.2f} vs {f['throughput']:.2f} tok/tick | "
              f"goodput {i['goodput']:.2f} vs {f['goodput']:.2f} "
              f"(immune shed {i['shed']})")

    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as fh:
        fh.write("seed,policy,throughput,p50_latency,p99_latency,goodput,"
                 "completed,shed\n")
        for r in rows:
            fh.write(f"{r[0]},{r[1]},{r[2]:.3f},{r[3]:.1f},{r[4]:.1f},"
                     f"{r[5]:.3f},{r[6]},{r[7]}\n")
    return rows


def main():
    jax.config.update("jax_platform_name", "cpu")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m",
                    choices=sorted(configs.ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="small trace for CI-class machines")
    ap.add_argument("--seeds", type=int, nargs="+", default=[0, 1, 2])
    args = ap.parse_args()

    n = 24 if args.smoke else 40
    rows = run(arch=args.arch, num_requests=n, seeds=tuple(args.seeds))
    imm = [r for r in rows if r[1] == "immune"]
    fifo = [r for r in rows if r[1] == "fifo"]
    p99_imm = float(np.mean([r[4] for r in imm]))
    p99_fifo = float(np.mean([r[4] for r in fifo]))
    print(f"mean p99: immune {p99_imm:.1f} vs fifo {p99_fifo:.1f} ticks "
          f"({'OK' if p99_imm <= p99_fifo else 'REGRESSION'}: immune must be "
          f"no worse)")


if __name__ == "__main__":
    main()
