"""Kernel microbenches (interpret-mode timings are indicative only on CPU; the
structural contract — correctness vs oracle and blocked VMEM tiling — is the
deliverable; see EXPERIMENTS.md §Methodology)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

import numpy as np

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.grid_step import grid_step, grid_step_ref
from repro.kernels.moe_gmm import gmm_ref, moe_gmm
from repro.kernels.paged_attention import paged_attention, paged_attention_ref


def _time(fn, *args, reps=3):
    fn(*args)                                    # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    key = jax.random.PRNGKey(0)
    rows = []

    b, h, hk, s, d = 1, 4, 2, 512, 64
    q = jax.random.normal(key, (b, h, s, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, hk, s, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, hk, s, d))
    rows.append(("flash_attention_interp", _time(
        lambda *a: flash_attention(*a, interpret=True), q, k, v),
        f"b{b}h{h}s{s}d{d}"))
    rows.append(("flash_attention_ref", _time(attention_ref, q, k, v),
                 f"b{b}h{h}s{s}d{d}"))

    e, c, dd, f = 8, 128, 64, 128
    x = jax.random.normal(key, (e, c, dd))
    w = jax.random.normal(key, (e, dd, f))
    sizes = jnp.full((e,), c, jnp.int32)
    rows.append(("moe_gmm_interp", _time(
        lambda *a: moe_gmm(*a, interpret=True), x, w, sizes), f"e{e}c{c}d{dd}f{f}"))
    rows.append(("moe_gmm_ref", _time(gmm_ref, x, w, sizes), f"e{e}c{c}d{dd}f{f}"))

    # paged decode attention: 16 slots x 4 pages of 128 tokens, GQA 4:1
    b_, h_, hk_, d_, page, maxp = 16, 8, 2, 64, 128, 4
    num_pages = b_ * maxp + 1
    qd = jax.random.normal(key, (b_, h_, d_))
    kp = jax.random.normal(jax.random.fold_in(key, 3), (num_pages, page, hk_, d_))
    vp = jax.random.normal(jax.random.fold_in(key, 4), (num_pages, page, hk_, d_))
    rng = np.random.default_rng(0)
    lens = rng.integers(1, maxp * page + 1, size=b_)
    free = list(range(1, num_pages))
    tbl = np.zeros((b_, maxp), np.int32)
    for i in range(b_):
        for j in range(-(-int(lens[i]) // page)):
            tbl[i, j] = free.pop()
    tbl, lens = jnp.asarray(tbl), jnp.asarray(lens, jnp.int32)
    rows.append(("paged_attention_interp", _time(
        lambda *a: paged_attention(*a, interpret=True), qd, kp, vp, tbl, lens),
        f"b{b_}h{h_}page{page}maxp{maxp}"))
    rows.append(("paged_attention_ref", _time(
        paged_attention_ref, qd, kp, vp, tbl, lens),
        f"b{b_}h{h_}page{page}maxp{maxp}"))

    lab = jax.random.randint(key, (80, 128), 0, 99, jnp.int32)
    cond = (jax.random.uniform(key, (80, 128)) < 0.5).astype(jnp.int32)
    rows.append(("grid_step_interp", _time(
        lambda *a: grid_step(*a, interpret=True), lab * cond, cond), "80x128"))
    rows.append(("grid_step_ref", _time(grid_step_ref, lab * cond, cond),
                 "80x128"))
    return rows


def main():
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
