"""Kernel microbenches (interpret-mode timings are indicative only on CPU; the
structural contract — correctness vs oracle and blocked VMEM tiling — is the
deliverable; see EXPERIMENTS.md §Methodology)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.grid_step import grid_step, grid_step_ref
from repro.kernels.moe_gmm import gmm_ref, moe_gmm


def _time(fn, *args, reps=3):
    fn(*args)                                    # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    key = jax.random.PRNGKey(0)
    rows = []

    b, h, hk, s, d = 1, 4, 2, 512, 64
    q = jax.random.normal(key, (b, h, s, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, hk, s, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, hk, s, d))
    rows.append(("flash_attention_interp", _time(
        lambda *a: flash_attention(*a, interpret=True), q, k, v),
        f"b{b}h{h}s{s}d{d}"))
    rows.append(("flash_attention_ref", _time(attention_ref, q, k, v),
                 f"b{b}h{h}s{s}d{d}"))

    e, c, dd, f = 8, 128, 64, 128
    x = jax.random.normal(key, (e, c, dd))
    w = jax.random.normal(key, (e, dd, f))
    sizes = jnp.full((e,), c, jnp.int32)
    rows.append(("moe_gmm_interp", _time(
        lambda *a: moe_gmm(*a, interpret=True), x, w, sizes), f"e{e}c{c}d{dd}f{f}"))
    rows.append(("moe_gmm_ref", _time(gmm_ref, x, w, sizes), f"e{e}c{c}d{dd}f{f}"))

    lab = jax.random.randint(key, (80, 128), 0, 99, jnp.int32)
    cond = (jax.random.uniform(key, (80, 128)) < 0.5).astype(jnp.int32)
    rows.append(("grid_step_interp", _time(
        lambda *a: grid_step(*a, interpret=True), lab * cond, cond), "80x128"))
    rows.append(("grid_step_ref", _time(grid_step_ref, lab * cond, cond),
                 "80x128"))
    return rows


def main():
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
